"""Layer-1 Pallas kernel: dense blocked GEMM (§4.1's 8-tile schedule,
re-thought for TPU).

The AMX schedule's essence — accumulators stay resident while input and
weight tiles stream — maps to a Pallas grid over (row block, column
block) with the full inner dimension contracted per program: the MXU
accumulates in registers/VMEM, and `BlockSpec` expresses the HBM→VMEM
schedule the paper wrote with explicit `tileloadd`s.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

COL_BLOCK = 128
ROW_BLOCK = 32


def _kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pad(a, axis, to):
    size = a.shape[axis]
    pad = (-size) % to
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@jax.jit
def dense_gemm(x, w):
    """``x[B, K] @ w[K, N]`` via the blocked Pallas kernel."""
    b, k_dim = x.shape
    _, n = w.shape
    xp = _pad(x, 0, ROW_BLOCK)
    wp = _pad(w, 1, COL_BLOCK)
    bp, np_ = xp.shape[0], wp.shape[1]
    out = pl.pallas_call(
        _kernel,
        grid=(bp // ROW_BLOCK, np_ // COL_BLOCK),
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, k_dim), lambda i, j: (i, 0)),
            pl.BlockSpec((k_dim, COL_BLOCK), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, COL_BLOCK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), x.dtype),
        interpret=True,
    )(xp, wp)
    return out[:b, :n]


@functools.partial(jax.jit, static_argnames=())
def dense_gemm_bf16(x, w):
    """BF16-storage variant: operands round through bfloat16 (as the AMX
    tile unit consumes them), accumulation in f32."""
    xb = x.astype(jnp.bfloat16).astype(jnp.float32)
    wb = w.astype(jnp.bfloat16).astype(jnp.float32)
    return dense_gemm(xb, wb)
