"""Pure-jnp/numpy oracles for every Layer-1 kernel.

These are the correctness contract: pytest asserts the Pallas kernels
match them to float tolerance across shapes, sparsities, and dtypes
(including hypothesis-generated cases).
"""

from __future__ import annotations

import numpy as np


def gemm(x: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Plain f32 GEMM."""
    return np.asarray(x, np.float32) @ np.asarray(w, np.float32)


def gemm_bf16(x: np.ndarray, w: np.ndarray) -> np.ndarray:
    """GEMM with operands rounded through bfloat16 storage."""
    import jax.numpy as jnp

    xb = np.asarray(jnp.asarray(x, jnp.bfloat16).astype(jnp.float32))
    wb = np.asarray(jnp.asarray(w, jnp.bfloat16).astype(jnp.float32))
    return xb @ wb


def gemm_int8(x: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Exact INT8 GEMM with INT32 accumulation."""
    return x.astype(np.int32) @ w.astype(np.int32)


def softmax(x: np.ndarray, axis: int = -1) -> np.ndarray:
    m = x.max(axis=axis, keepdims=True)
    e = np.exp(x - m)
    return e / e.sum(axis=axis, keepdims=True)


def decode_attention(
    q: np.ndarray, k: np.ndarray, v: np.ndarray
) -> np.ndarray:
    """Single-head decode attention oracle.

    q: ``[group, hd]``; k, v: ``[ctx, hd]`` → ``[group, hd]``.
    """
    hd = q.shape[-1]
    scores = (q @ k.T) / np.sqrt(hd)
    return softmax(scores, axis=-1) @ v


def gqa_decode_attention(
    q: np.ndarray, k: np.ndarray, v: np.ndarray
) -> np.ndarray:
    """GQA oracle: q ``[kv_heads, group, hd]``, k/v ``[kv_heads, ctx, hd]``."""
    return np.stack(
        [decode_attention(q[h], k[h], v[h]) for h in range(q.shape[0])]
    )
