"""Layer-1 Pallas kernel: load-as-sparse / compute-as-dense GEMM (§4.3).

Grid = one program per 16-neuron column block (the paper's
parallelization dimension; each block owns a contiguous slice of the
compressed stream — the `weight_value_index` idea maps to the per-block
``vals`` rows). Each program:

1. streams its bitmap + packed values block from HBM (the only weight
   traffic),
2. decompresses into a dense ``[K, 16]`` block in VMEM
   (:mod:`common.decompress_block`),
3. feeds the MXU: ``out_block = x @ W_block`` with f32 accumulation.

``interpret=True`` always — real-TPU lowering would emit a Mosaic
custom-call the CPU PJRT plugin cannot execute (see DESIGN.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import COLS_PER_BLOCK, decompress_block


def _kernel(x_ref, mask_ref, vals_ref, o_ref):
    w_block = decompress_block(mask_ref[0, :], vals_ref[0, :], x_ref.dtype)
    o_ref[...] = jnp.dot(
        x_ref[...], w_block, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_logical",))
def sparse_gemm(x, mask, vals, n_logical: int):
    """``x[B, K] @ unpack(mask, vals)[K, N]`` without densifying in HBM.

    Args:
      x: ``f32[B, K]`` activations.
      mask: ``uint32[cb, K]`` bitmap stream.
      vals: ``f32[cb, Vmax]`` packed non-zero stream.
      n_logical: unpadded output width ``N`` (≤ ``cb * 16``).

    Returns:
      ``f32[B, N]``.
    """
    b, k_dim = x.shape
    cb = mask.shape[0]
    out = pl.pallas_call(
        _kernel,
        grid=(cb,),
        in_specs=[
            pl.BlockSpec((b, k_dim), lambda i: (0, 0)),
            pl.BlockSpec((1, k_dim), lambda i: (i, 0)),
            pl.BlockSpec((1, vals.shape[1]), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b, COLS_PER_BLOCK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, cb * COLS_PER_BLOCK), x.dtype),
        interpret=True,
    )(x, mask, vals)
    return out[:, :n_logical]
