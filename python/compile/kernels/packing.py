"""Host-side packing into the SparAMX bitmap + values format (Layer 1).

This is the Python twin of the Rust `sparse::format` module, specialized
for the Pallas kernels' layout:

* the weight matrix ``W[K][N]`` is carved into **column blocks** of 16
  output neurons (the paper's AMX tile width / the kernels' grid
  dimension);
* per column block ``b``, ``mask[b, k]`` is a 16-bit bitmap (stored
  uint32) over the block's 16 columns at inner-dim position ``k``
  (``bit c`` set ⟺ ``W[k, 16b + c] != 0``);
* ``vals[b]`` holds the block's non-zeros in ``k``-major, then
  column-order — exactly the order a `vpexpandw`-style expansion
  consumes — zero-padded to the max block ``nnz`` so the array is
  rectangular for XLA.

Packing happens once at model-load time (build time here); the kernels
never see dense weights in HBM.
"""

from __future__ import annotations

import numpy as np

COLS_PER_BLOCK = 16


def pack_mask_vals(w: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Pack dense ``w[K, N]`` → ``(mask[cb, K] uint32, vals[cb, Vmax])``.

    ``N`` is zero-padded up to a multiple of 16 (padding columns carry no
    mask bits, hence no values). ``vals`` keeps ``w``'s dtype.
    """
    if w.ndim != 2:
        raise ValueError(f"expected 2-D weights, got shape {w.shape}")
    k_dim, n = w.shape
    cb = -(-n // COLS_PER_BLOCK)
    n_pad = cb * COLS_PER_BLOCK
    if n_pad != n:
        w = np.concatenate([w, np.zeros((k_dim, n_pad - n), dtype=w.dtype)], axis=1)

    blocks = w.reshape(k_dim, cb, COLS_PER_BLOCK).transpose(1, 0, 2)  # [cb, K, 16]
    nz = blocks != 0
    # mask[b, k] = sum_c nz[b,k,c] << c
    weights_of_bits = (1 << np.arange(COLS_PER_BLOCK, dtype=np.uint32))
    mask = (nz.astype(np.uint32) * weights_of_bits).sum(axis=2).astype(np.uint32)

    counts = nz.reshape(cb, -1).sum(axis=1)
    vmax = max(int(counts.max()) if cb else 0, 1)
    vals = np.zeros((cb, vmax), dtype=w.dtype)
    for b in range(cb):
        vals[b, : counts[b]] = blocks[b][nz[b]]  # row-major: k-major, col order
    return mask, vals


def unpack_mask_vals(
    mask: np.ndarray, vals: np.ndarray, n: int
) -> np.ndarray:
    """Inverse of :func:`pack_mask_vals` (testing oracle)."""
    cb, k_dim = mask.shape
    out = np.zeros((k_dim, cb * COLS_PER_BLOCK), dtype=vals.dtype)
    for b in range(cb):
        vi = 0
        for k in range(k_dim):
            m = int(mask[b, k])
            for c in range(COLS_PER_BLOCK):
                if m >> c & 1:
                    out[k, b * COLS_PER_BLOCK + c] = vals[b, vi]
                    vi += 1
    return out[:, :n]


def sparsity_of(mask: np.ndarray, k_dim: int, n: int) -> float:
    """Observed sparsity over the logical (unpadded) matrix."""
    nnz = int(
        np.unpackbits(mask.astype(np.uint32).view(np.uint8), bitorder="little").sum()
    )
    return 1.0 - nnz / float(k_dim * n)


def magnitude_prune(w: np.ndarray, sparsity: float) -> np.ndarray:
    """Zero the smallest-|w| fraction (paper §6.1), matching the Rust
    implementation's exact-count semantics."""
    w = np.asarray(w)
    k = int(round(w.size * float(np.clip(sparsity, 0.0, 1.0))))
    if k == 0:
        return w.copy()
    if k >= w.size:
        return np.zeros_like(w)
    flat = np.abs(w).ravel()
    thresh = np.partition(flat, k - 1)[k - 1]
    out = w.copy()
    below = np.abs(out) < thresh
    out[below] = 0
    pruned = int(below.sum())
    if pruned < k:
        ties = np.argwhere(np.abs(out) == thresh)
        for idx in ties[: k - pruned]:
            out[tuple(idx)] = 0
    return out
