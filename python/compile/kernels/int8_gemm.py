"""Layer-1 Pallas kernel: INT8 sparse GEMM (§4.5).

Same structure as :mod:`sparse_gemm` with 8-bit values and INT32
accumulation (`tdpbssd`'s contract). The bitmap stays one bit per
element; values are an int8 stream, so a 50 %-sparse INT8 layer moves
roughly ``1/8 + 0.5`` of its dense bytes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import COLS_PER_BLOCK, decompress_block


def _kernel(x_ref, mask_ref, vals_ref, o_ref):
    w_block = decompress_block(mask_ref[0, :], vals_ref[0, :], jnp.int8)
    o_ref[...] = jax.lax.dot_general(
        x_ref[...],
        w_block,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


@functools.partial(jax.jit, static_argnames=("n_logical",))
def int8_sparse_gemm(x, mask, vals, n_logical: int):
    """``int8[B, K] @ unpack(mask, vals)[K, N] → int32[B, N]``."""
    b, k_dim = x.shape
    cb = mask.shape[0]
    out = pl.pallas_call(
        _kernel,
        grid=(cb,),
        in_specs=[
            pl.BlockSpec((b, k_dim), lambda i: (0, 0)),
            pl.BlockSpec((1, k_dim), lambda i: (i, 0)),
            pl.BlockSpec((1, vals.shape[1]), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b, COLS_PER_BLOCK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, cb * COLS_PER_BLOCK), jnp.int32),
        interpret=True,
    )(x, mask, vals)
    return out[:, :n_logical]
