"""AOT export: lower the Layer-2 model (with its Layer-1 Pallas kernels)
to HLO **text** and write the artifact bundle Rust serves from.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids (see /opt/xla-example/README.md).

Artifacts written to ``--out`` (default ``../artifacts``):

| file | computation |
|---|---|
| ``decode_step.hlo.txt``  | one batched decode step (params as inputs) |
| ``prefill.hlo.txt``      | fixed-length prompt prefill |
| ``eval_logits.hlo.txt``  | per-position logits for perplexity |
| ``sparse_gemm.hlo.txt``  | standalone L1 sparse kernel (fixed shape) |
| ``int8_gemm.hlo.txt``    | standalone L1 INT8 sparse kernel |
| ``weights.bin``          | trained parameters (see io.py) |
| ``eval_tokens.bin``      | held-out eval tokens |
| ``manifest.json``        | shapes + input orders for the Rust runtime |

Run: ``python -m compile.aot --out ../artifacts [--steps N]``.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import io, model, trainer
from .kernels.int8_gemm import int8_sparse_gemm
from .kernels.sparse_gemm import sparse_gemm

# Fixed shapes for the serving artifacts (recorded in the manifest).
DECODE_BATCH = 4
GEMM_SHAPE = dict(batch=2, k=128, n=352, vmax=None)  # vmax filled at export


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def export(out_dir: str, train_steps: int) -> None:
    os.makedirs(out_dir, exist_ok=True)
    cfg = model.TINY_CONFIG
    layers, kvh, hd = cfg["layers"], cfg["kv_heads"], cfg["head_dim"]
    maxc, vocab = cfg["max_ctx"], cfg["vocab"]
    b = DECODE_BATCH

    # ---- train the tiny checkpoint -----------------------------------
    params, loss_log, eval_tokens = trainer.train(steps=train_steps)
    io.write_weights(f"{out_dir}/weights.bin", trainer.flatten_params(params))
    io.write_tokens(f"{out_dir}/eval_tokens.bin", eval_tokens)
    with open(f"{out_dir}/train_log.txt", "w") as f:
        for step, loss in loss_log:
            f.write(f"{step}\t{loss:.6f}\n")

    param_specs = jax.tree.map(lambda x: spec(x.shape), params)
    manifest: dict = {
        "config": cfg,
        "decode_batch": b,
        "prefill_len": model.PREFILL_LEN,
        "eval_len": model.EVAL_LEN,
        "params": [
            {"name": n, "shape": list(s)} for n, s in model.param_manifest(params)
        ],
        "train_loss": [[s, l] for s, l in loss_log],
        "artifacts": {},
    }

    # ---- decode_step --------------------------------------------------
    lowered = jax.jit(model.decode_step).lower(
        param_specs,
        spec((b,), jnp.int32),
        spec((b,), jnp.int32),
        spec((layers, b, kvh, maxc, hd)),
        spec((layers, b, kvh, maxc, hd)),
        spec((b,), jnp.int32),
    )
    _write(out_dir, "decode_step", lowered, manifest,
           inputs="params..., token[i32 B], pos[i32 B], k_cache, v_cache, cache_len[i32 B]",
           outputs="logits[B,V], k_cache', v_cache'")

    # ---- prefill -------------------------------------------------------
    lowered = jax.jit(model.prefill).lower(
        param_specs, spec((b, model.PREFILL_LEN), jnp.int32)
    )
    _write(out_dir, "prefill", lowered, manifest,
           inputs="params..., tokens[i32 B,S]",
           outputs="logits[B,V], k[L,B,kvh,S,hd], v[L,B,kvh,S,hd]")

    # ---- eval_logits ----------------------------------------------------
    lowered = jax.jit(model.eval_logits).lower(
        param_specs, spec((1, model.EVAL_LEN), jnp.int32)
    )
    _write(out_dir, "eval_logits", lowered, manifest,
           inputs="params..., tokens[i32 1,S]", outputs="logits[1,S,V]")

    # ---- standalone L1 kernels ------------------------------------------
    k_dim, n = GEMM_SHAPE["k"], GEMM_SHAPE["n"]
    cb = -(-n // 16)
    vmax = k_dim * 16  # worst case: fully dense block
    GEMM_SHAPE["vmax"] = vmax
    lowered = jax.jit(sparse_gemm, static_argnames=("n_logical",)).lower(
        spec((GEMM_SHAPE["batch"], k_dim)),
        spec((cb, k_dim), jnp.uint32),
        spec((cb, vmax)),
        n_logical=n,
    )
    _write(out_dir, "sparse_gemm", lowered, manifest,
           inputs=f"x[{GEMM_SHAPE['batch']},{k_dim}], mask[{cb},{k_dim}]u32, vals[{cb},{vmax}]",
           outputs=f"out[{GEMM_SHAPE['batch']},{n}]")

    lowered = jax.jit(int8_sparse_gemm, static_argnames=("n_logical",)).lower(
        spec((GEMM_SHAPE["batch"], k_dim), jnp.int8),
        spec((cb, k_dim), jnp.uint32),
        spec((cb, vmax), jnp.int8),
        n_logical=n,
    )
    _write(out_dir, "int8_gemm", lowered, manifest,
           inputs="x[i8], mask[u32], vals[i8]", outputs="out[i32]")

    manifest["gemm_shape"] = GEMM_SHAPE
    with open(f"{out_dir}/manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"artifacts complete in {out_dir}")


def _write(out_dir, name, lowered, manifest, inputs, outputs):
    text = to_hlo_text(lowered)
    path = f"{out_dir}/{name}.hlo.txt"
    with open(path, "w") as f:
        f.write(text)
    manifest["artifacts"][name] = {
        "file": f"{name}.hlo.txt",
        "inputs": inputs,
        "outputs": outputs,
        "hlo_bytes": len(text),
    }
    print(f"wrote {path} ({len(text)} chars)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=300,
                    help="training steps for the tiny checkpoint")
    args = ap.parse_args()
    export(args.out, args.steps)


if __name__ == "__main__":
    main()
