"""Layer-2 model tests: shapes, decode/prefill/forward consistency, loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model, trainer


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0))


def test_forward_shapes(params):
    toks = jnp.zeros((2, 12), jnp.int32)
    logits = model.forward_seq(params, toks)
    assert logits.shape == (2, 12, model.TINY_CONFIG["vocab"])


def test_prefill_matches_forward(params):
    rng = np.random.default_rng(0)
    t = jnp.asarray(rng.integers(0, 255, size=(2, 8)), jnp.int32)
    pf_logits, ks, vs = model.prefill(params, t)
    fs = model.forward_seq(params, t)
    np.testing.assert_allclose(pf_logits, fs[:, -1], atol=1e-3, rtol=1e-3)
    cfg = model.TINY_CONFIG
    assert ks.shape == (cfg["layers"], 2, cfg["kv_heads"], 8, cfg["head_dim"])
    assert vs.shape == ks.shape


def test_decode_step_matches_forward(params):
    """The KV-cached decode path must agree with the full recompute."""
    cfg = model.TINY_CONFIG
    rng = np.random.default_rng(1)
    B, S = 2, 6
    t = jnp.asarray(rng.integers(0, 255, size=(B, S)), jnp.int32)
    _, ks, vs = model.prefill(params, t)
    maxc = cfg["max_ctx"]
    k_cache = jnp.zeros((cfg["layers"], B, cfg["kv_heads"], maxc, cfg["head_dim"]))
    v_cache = jnp.zeros_like(k_cache)
    k_cache = k_cache.at[:, :, :, :S].set(ks)
    v_cache = v_cache.at[:, :, :, :S].set(vs)
    nxt = jnp.asarray([65, 66], jnp.int32)
    lg, nk, nv = model.decode_step(
        params, nxt, jnp.full((B,), S, jnp.int32), k_cache, v_cache,
        jnp.full((B,), S + 1, jnp.int32),
    )
    t2 = jnp.concatenate([t, nxt[:, None]], axis=1)
    fs2 = model.forward_seq(params, t2)
    np.testing.assert_allclose(lg, fs2[:, -1], atol=2e-3, rtol=1e-2)
    assert nk.shape == k_cache.shape and nv.shape == v_cache.shape


def test_decode_step_mixed_cache_lens(params):
    """Continuous batching: slots at different progress must not interact."""
    cfg = model.TINY_CONFIG
    rng = np.random.default_rng(2)
    B = 2
    maxc = cfg["max_ctx"]
    # slot 0 has 4 cached tokens, slot 1 has 7
    t = jnp.asarray(rng.integers(0, 255, size=(B, 7)), jnp.int32)
    _, ks, vs = model.prefill(params, t)
    k_cache = jnp.zeros((cfg["layers"], B, cfg["kv_heads"], maxc, cfg["head_dim"]))
    v_cache = jnp.zeros_like(k_cache)
    k_cache = k_cache.at[:, :, :, :7].set(ks)
    v_cache = v_cache.at[:, :, :, :7].set(vs)
    nxt = jnp.asarray([10, 20], jnp.int32)
    pos = jnp.asarray([4, 7], jnp.int32)
    clen = jnp.asarray([5, 8], jnp.int32)
    lg, _, _ = model.decode_step(params, nxt, pos, k_cache, v_cache, clen)
    # slot 0's logits must equal a standalone 5-token forward
    t0 = jnp.concatenate([t[0:1, :4], nxt[0:1, None]], axis=1)
    fs0 = model.forward_seq(params, t0)
    np.testing.assert_allclose(lg[0], fs0[0, -1], atol=2e-3, rtol=1e-2)


def test_rope_order_dependence(params):
    """Token order must matter (RoPE + causality): the final-position
    logits of [a, b, c] and [b, a, c] must differ."""
    l1 = model.forward_seq(params, jnp.asarray([[65, 66, 67]], jnp.int32))
    l2 = model.forward_seq(params, jnp.asarray([[66, 65, 67]], jnp.int32))
    assert not np.allclose(l1[0, -1], l2[0, -1], atol=1e-4)


def test_loss_decreases_in_short_training():
    params, log, _ = trainer.train(steps=30, batch=8, seq=32, log_every=29)
    assert log[-1][1] < log[0][1] * 0.8, f"loss did not drop: {log}"


def test_param_manifest_is_deterministic(params):
    m1 = model.param_manifest(params)
    m2 = model.param_manifest(model.init_params(jax.random.PRNGKey(7)))
    assert [n for n, _ in m1] == [n for n, _ in m2]
    assert len(m1) == 2 + 1 + 9 * model.TINY_CONFIG["layers"]


def test_synth_corpus_is_text():
    c = trainer.synth_corpus(10, 0)
    text = bytes(c).decode()
    assert "times." in text
    # deterministic
    assert np.array_equal(c, trainer.synth_corpus(10, 0))
