"""Interchange-format tests + AOT artifact smoke checks."""

import os

import numpy as np
import pytest

from compile import io

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_weights_roundtrip(tmp_path):
    path = str(tmp_path / "w.bin")
    tensors = [
        ("emb", np.arange(12, dtype=np.float32).reshape(3, 4)),
        ("g", np.array([1.5, -2.0], np.float32)),
    ]
    io.write_weights(path, tensors)
    back = io.read_weights(path)
    assert back[0][0] == "emb"
    np.testing.assert_array_equal(back[0][1], tensors[0][1])
    np.testing.assert_array_equal(back[1][1], tensors[1][1])


def test_tokens_roundtrip(tmp_path):
    path = str(tmp_path / "t.bin")
    toks = np.array([0, 255, 65], np.uint8)
    io.write_tokens(path, toks)
    np.testing.assert_array_equal(io.read_tokens(path), toks)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestArtifacts:
    def test_manifest_lists_all_artifacts(self):
        import json

        with open(os.path.join(ART, "manifest.json")) as f:
            man = json.load(f)
        for name in ["decode_step", "prefill", "eval_logits", "sparse_gemm", "int8_gemm"]:
            assert name in man["artifacts"]
            path = os.path.join(ART, man["artifacts"][name]["file"])
            assert os.path.getsize(path) > 1000, name

    def test_hlo_text_is_parseable_header(self):
        with open(os.path.join(ART, "decode_step.hlo.txt")) as f:
            head = f.read(200)
        assert "HloModule" in head

    def test_weights_match_manifest_order(self):
        import json

        with open(os.path.join(ART, "manifest.json")) as f:
            man = json.load(f)
        weights = io.read_weights(os.path.join(ART, "weights.bin"))
        assert [w[0] for w in weights] == [p["name"] for p in man["params"]]
        for (name, arr), p in zip(weights, man["params"]):
            assert list(arr.shape) == p["shape"], name

    def test_training_converged(self):
        import json

        with open(os.path.join(ART, "manifest.json")) as f:
            man = json.load(f)
        losses = [l for _, l in man["train_loss"]]
        assert losses[-1] < losses[0] * 0.3, "training did not converge"
