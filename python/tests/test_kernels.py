"""Layer-1 kernel correctness: every Pallas kernel vs its pure oracle.

This is the core correctness signal of the compile path — the same
kernels get lowered into the AOT artifacts Rust serves from.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import packing, ref
from compile.kernels.dense_gemm import dense_gemm, dense_gemm_bf16
from compile.kernels.int8_gemm import int8_sparse_gemm
from compile.kernels.sparse_gemm import sparse_gemm
from compile.kernels.attention import sparse_kv_attention

RNG = np.random.default_rng(1234)


def random_sparse(k, n, sparsity, dtype=np.float32):
    w = RNG.normal(size=(k, n)).astype(np.float32)
    w = packing.magnitude_prune(w, sparsity)
    return w.astype(dtype)


# ---------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------

class TestPacking:
    @pytest.mark.parametrize("k,n", [(32, 16), (64, 37), (50, 100), (7, 5)])
    @pytest.mark.parametrize("sparsity", [0.0, 0.5, 0.9, 1.0])
    def test_roundtrip(self, k, n, sparsity):
        w = random_sparse(k, n, sparsity)
        mask, vals = packing.pack_mask_vals(w)
        assert np.array_equal(packing.unpack_mask_vals(mask, vals, n), w)

    def test_mask_bit_positions(self):
        w = np.zeros((4, 16), np.float32)
        w[2, 3] = 5.0
        mask, vals = packing.pack_mask_vals(w)
        assert mask.shape == (1, 4)
        assert mask[0, 2] == 1 << 3
        assert vals[0, 0] == 5.0

    def test_prune_exact_count(self):
        w = RNG.normal(size=(40, 25)).astype(np.float32)
        p = packing.magnitude_prune(w, 0.3)
        assert (p == 0).sum() == round(0.3 * w.size)

    def test_prune_keeps_largest(self):
        w = np.array([[0.1, -9.0, 0.2, 3.0]], np.float32)
        p = packing.magnitude_prune(w, 0.5)
        assert p.tolist() == [[0.0, -9.0, 0.0, 3.0]]


# ---------------------------------------------------------------------
# sparse GEMM
# ---------------------------------------------------------------------

class TestSparseGemm:
    @pytest.mark.parametrize("b,k,n", [(1, 32, 16), (4, 64, 48), (3, 50, 37)])
    @pytest.mark.parametrize("sparsity", [0.0, 0.5, 0.95])
    def test_matches_ref(self, b, k, n, sparsity):
        w = random_sparse(k, n, sparsity)
        mask, vals = packing.pack_mask_vals(w)
        x = RNG.normal(size=(b, k)).astype(np.float32)
        got = np.asarray(sparse_gemm(x, mask, vals, n))
        np.testing.assert_allclose(got, ref.gemm(x, w), atol=1e-4, rtol=1e-4)

    def test_all_zero_weights(self):
        w = np.zeros((32, 16), np.float32)
        mask, vals = packing.pack_mask_vals(w)
        x = RNG.normal(size=(2, 32)).astype(np.float32)
        assert np.all(np.asarray(sparse_gemm(x, mask, vals, 16)) == 0)

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 4),
        k=st.integers(1, 96),
        n=st.integers(1, 80),
        sparsity=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**31),
    )
    def test_hypothesis_sweep(self, b, k, n, sparsity, seed):
        rng = np.random.default_rng(seed)
        w = packing.magnitude_prune(
            rng.normal(size=(k, n)).astype(np.float32), sparsity
        )
        mask, vals = packing.pack_mask_vals(w)
        x = rng.normal(size=(b, k)).astype(np.float32)
        got = np.asarray(sparse_gemm(x, mask, vals, n))
        np.testing.assert_allclose(got, ref.gemm(x, w), atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------
# dense GEMM
# ---------------------------------------------------------------------

class TestDenseGemm:
    @pytest.mark.parametrize("b,k,n", [(1, 16, 8), (33, 48, 130), (5, 128, 352)])
    def test_matches_ref(self, b, k, n):
        x = RNG.normal(size=(b, k)).astype(np.float32)
        w = RNG.normal(size=(k, n)).astype(np.float32)
        np.testing.assert_allclose(
            np.asarray(dense_gemm(x, w)), ref.gemm(x, w), atol=1e-4, rtol=1e-4
        )

    def test_bf16_variant_rounds_operands(self):
        x = RNG.normal(size=(2, 32)).astype(np.float32)
        w = RNG.normal(size=(32, 16)).astype(np.float32)
        got = np.asarray(dense_gemm_bf16(x, w))
        np.testing.assert_allclose(got, ref.gemm_bf16(x, w), atol=1e-3, rtol=1e-2)


# ---------------------------------------------------------------------
# INT8 GEMM
# ---------------------------------------------------------------------

class TestInt8Gemm:
    @pytest.mark.parametrize("b,k,n", [(1, 64, 32), (4, 100, 30)])
    @pytest.mark.parametrize("sparsity", [0.0, 0.6])
    def test_exact_vs_ref(self, b, k, n, sparsity):
        w = RNG.integers(-100, 100, size=(k, n)).astype(np.int8)
        w[RNG.random(size=w.shape) < sparsity] = 0
        mask, vals = packing.pack_mask_vals(w)
        x = RNG.integers(-100, 100, size=(b, k)).astype(np.int8)
        got = np.asarray(int8_sparse_gemm(x, mask, vals, n))
        assert np.array_equal(got, ref.gemm_int8(x, w))

    def test_accumulator_does_not_overflow_int8(self):
        # worst-case accumulation requires int32: 128 * 127 * 127 > 2^21
        k = 128
        w = np.full((k, 16), 127, np.int8)
        mask, vals = packing.pack_mask_vals(w)
        x = np.full((1, k), 127, np.int8)
        got = np.asarray(int8_sparse_gemm(x, mask, vals, 16))
        assert got[0, 0] == 127 * 127 * k


# ---------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------

def pack_head_kv(k, v):
    """Pack one head's K/V for the kernel (Kᵀ and V layouts)."""
    kt_mask, kt_vals = packing.pack_mask_vals(np.ascontiguousarray(k.T))
    v_mask, v_vals = packing.pack_mask_vals(v)
    return kt_mask, kt_vals, v_mask, v_vals


def pack_all_heads(k, v):
    packed = [pack_head_kv(k[h], v[h]) for h in range(k.shape[0])]
    def stack(i):
        arrs = [p[i] for p in packed]
        vmax = max(a.shape[1] for a in arrs)
        return np.stack(
            [np.pad(a, [(0, 0), (0, vmax - a.shape[1])]) for a in arrs]
        )
    return stack(0), stack(1), stack(2), stack(3)


class TestAttention:
    @pytest.mark.parametrize("sparsity", [0.0, 0.4])
    def test_matches_ref(self, sparsity):
        kv_heads, group, hd, ctx, max_dyn = 2, 2, 16, 32, 4
        q = RNG.normal(size=(kv_heads, group, hd)).astype(np.float32)
        k = random_sparse(kv_heads * ctx, hd, sparsity).reshape(kv_heads, ctx, hd)
        v = random_sparse(kv_heads * ctx, hd, sparsity).reshape(kv_heads, ctx, hd)
        kt_mask, kt_vals, v_mask, v_vals = pack_all_heads(k, v)
        k_dyn = RNG.normal(size=(kv_heads, max_dyn, hd)).astype(np.float32)
        v_dyn = RNG.normal(size=(kv_heads, max_dyn, hd)).astype(np.float32)
        dyn_len = np.array([3, 1], np.int32)
        got = np.asarray(
            sparse_kv_attention(q, kt_mask, kt_vals, v_mask, v_vals, k_dyn, v_dyn, dyn_len)
        )
        for h in range(kv_heads):
            kk = np.concatenate([k[h], k_dyn[h, : dyn_len[h]]])
            vv = np.concatenate([v[h], v_dyn[h, : dyn_len[h]]])
            want = ref.decode_attention(q[h], kk, vv)
            np.testing.assert_allclose(got[h], want, atol=1e-3, rtol=1e-3)

    def test_empty_dynamic_tail(self):
        kv_heads, group, hd, ctx = 1, 1, 8, 16
        q = RNG.normal(size=(kv_heads, group, hd)).astype(np.float32)
        k = RNG.normal(size=(kv_heads, ctx, hd)).astype(np.float32)
        v = RNG.normal(size=(kv_heads, ctx, hd)).astype(np.float32)
        kt_mask, kt_vals, v_mask, v_vals = pack_all_heads(k, v)
        k_dyn = np.zeros((kv_heads, 2, hd), np.float32)
        v_dyn = np.zeros((kv_heads, 2, hd), np.float32)
        got = np.asarray(
            sparse_kv_attention(
                q, kt_mask, kt_vals, v_mask, v_vals, k_dyn, v_dyn,
                np.zeros(kv_heads, np.int32),
            )
        )
        want = ref.decode_attention(q[0], k[0], v[0])
        np.testing.assert_allclose(got[0], want, atol=1e-3, rtol=1e-3)
